"""Adaptive execution (PR 5): the rate-tuned wave autoscaler and the async
checkpoint writer must be pure *execution* changes — output bit-identical
to the fixed-W synchronous reference for EVERY width trajectory (adaptive,
adversarially scheduled, oscillating, ragged-tailed) and every checkpoint
mode (sync, async, async killed mid-write) — with the bucket ladder's
re-jit bound asserted and exact resume semantics preserved."""
import os

import numpy as np
import jax.numpy as jnp
import pytest

from repro.core import (ChunkedSource, ExemplarClustering, Knapsack,
                        PartitionMatroid, TreeConfig, centralized_greedy,
                        tree_maximize)
from repro.data.sources import ShardedSource
from repro.engine import (AutotunePlanner, FixedWidthPlanner,
                          ScheduledWidthPlanner, WaveTrace, bucket_ladder,
                          shape_bound, snap_down, suggest_prefetch_depth)


def _setup(n=601, d=8, ne=128, seed=0):
    r = np.random.default_rng(seed)
    data = r.standard_normal((n, d)).astype(np.float32)
    E = data[r.choice(n, ne, replace=False)]
    return data, ExemplarClustering(jnp.asarray(E))


def _assert_identical(a, b):
    np.testing.assert_array_equal(a.sel_rows, b.sel_rows)
    np.testing.assert_array_equal(a.sel_mask, b.sel_mask)
    assert a.value == b.value                      # bit-identical, no rtol
    assert a.oracle_calls == b.oracle_calls
    assert a.rounds == b.rounds
    assert a.machines_per_round == b.machines_per_round
    assert a.round_values == b.round_values


def _trace(machines, gather_s, solve_s, wave=0):
    return WaveTrace(wave=wave, machines=machines, rows=machines,
                     bytes_moved=4 * machines, gather_s=gather_s,
                     solve_s=solve_s)


# ---------------------------------------------------------------------------
# controller units: ladder, snapping, planner policies
# ---------------------------------------------------------------------------


def test_bucket_ladder_and_shape_bound():
    assert bucket_ladder(1, 8) == [1, 2, 4, 8]
    assert bucket_ladder(2, 16) == [2, 4, 8, 16]
    assert bucket_ladder(2, 12) == [2, 4, 8, 12]   # non-pow2 cap is a rung
    assert bucket_ladder(4, 4) == [4]
    for ndev, wmax in ((1, 8), (2, 12), (1, 1000), (4, 64)):
        ladder = bucket_ladder(ndev, wmax)
        assert len(ladder) <= shape_bound(ndev, wmax)
        assert all(w % ndev == 0 for w in ladder)
        assert ladder[-1] == wmax
    assert snap_down([1, 2, 4, 8], 7) == 4
    assert snap_down([1, 2, 4, 8], 8) == 8
    assert snap_down([2, 4], 3) == 2


def test_fixed_planner_keeps_legacy_wave_boundaries():
    p = FixedWidthPlanner(3)
    assert [p.next_width(r) for r in (10, 7, 4, 1)] == [3, 3, 3, 1]


def test_scheduled_planner_replays_and_clamps():
    p = ScheduledWidthPlanner([1, 7, 2])
    assert p.next_width(100) == 1
    assert p.next_width(100) == 7
    assert p.next_width(100) == 2
    assert p.next_width(100) == 2          # exhausted: repeat last
    assert p.next_width(1) == 1            # clamped to remaining


def test_autotuner_climbs_when_larger_buckets_measure_better():
    """Per-wave cost dominated by a fixed term ⇒ per-machine cost falls
    with width ⇒ the controller must walk up the ladder and stay there."""
    ladder = bucket_ladder(1, 16)
    p = AutotunePlanner(ladder, start=1, warmup=1)
    widths = []
    for _ in range(24):
        w = p.next_width(1_000)
        widths.append(w)
        # fixed 10ms per wave + 1ms per machine on the binding track
        p.observe(_trace(w, gather_s=0.010 + 0.001 * w, solve_s=0.001))
    assert widths[-1] == 16, widths          # reached (and held) the top
    assert widths == sorted(widths), widths  # monotone climb, no thrash
    assert set(widths) <= set(ladder)


def test_autotuner_backs_off_on_regression():
    """When a larger bucket measures *worse* per machine (e.g. it blows a
    host cache), the controller must step back and settle below it."""
    ladder = bucket_ladder(1, 16)
    p = AutotunePlanner(ladder, start=1, warmup=1)
    widths = []
    for _ in range(30):
        w = p.next_width(1_000)
        widths.append(w)
        # amortizing fixed overhead rewards climbing — until width ≥ 8
        # falls off a cliff (10× per-machine cost)
        g = 0.008 + 0.001 * w if w < 8 else 0.020 * w
        p.observe(_trace(w, gather_s=g, solve_s=0.0001))
    assert widths[-1] < 8, widths            # settled under the cliff
    assert 8 in widths or 16 in widths       # it did probe upward first


def test_autotuner_converges_at_interior_optimum():
    """An optimum strictly inside the ladder must be a fixed point: after
    probing the worse rung above it, the controller holds — it must NOT
    re-compare against the rung it just left, read 'improving', and cycle
    past the optimum forever."""
    ladder = bucket_ladder(1, 16)
    cost = {1: 1.0, 2: 0.55, 4: 0.30, 8: 0.45, 16: 0.90}   # optimum W=4
    p = AutotunePlanner(ladder, start=1, warmup=1)
    widths = []
    for _ in range(40):
        w = p.next_width(10_000)
        widths.append(w)
        p.observe(_trace(w, gather_s=cost[w] * w, solve_s=0.0001))
    assert 8 in widths                       # it probed past the optimum
    assert all(w == 4 for w in widths[-10:]), widths  # then held at it


def test_autotuner_survives_forced_oscillation():
    """Adversarial feedback — costs that always make the *other* rung look
    better — must keep the controller on the ladder (never an invalid
    width, never outside [1, remaining]) and keep making progress."""
    ladder = bucket_ladder(1, 8)
    p = AutotunePlanner(ladder, start=2, warmup=1)
    flip = [False]
    total = 0
    for _ in range(40):
        w = p.next_width(10_000 - total)
        assert w in ladder and 1 <= w <= 10_000 - total
        total += w
        flip[0] = not flip[0]
        # alternate which width looks expensive → worst-case thrash
        per_m = 0.01 if flip[0] else 0.0001
        p.observe(_trace(w, gather_s=per_m * w, solve_s=0.0001))
    assert total > 40                        # progress was made regardless


def test_autotuner_discards_first_sample_at_new_rung():
    """The first wave at a fresh rung pays XLA compile; that sample must
    not poison the rung's score (the controller would bounce off every
    new rung and never climb)."""
    ladder = bucket_ladder(1, 8)
    p = AutotunePlanner(ladder, start=1, warmup=1)
    visits: dict[int, int] = {}
    widths = []
    for _ in range(24):
        w = p.next_width(1_000)
        widths.append(w)
        visits[w] = visits.get(w, 0) + 1
        # steady-state per-machine cost falls with width, but the FIRST
        # wave at each width is 50× more expensive (compile)
        per_m = (0.050 if visits[w] == 1 else 0.001) * (8.0 / w)
        p.observe(_trace(w, gather_s=per_m * w, solve_s=0.0001))
    assert widths[-1] == 8, widths           # compile spikes did not pin it


def test_suggest_prefetch_depth():
    assert suggest_prefetch_depth(0.0, 0.0) == 2          # no data → default
    assert suggest_prefetch_depth(0.1, 10.0) == 2         # compute-bound
    assert suggest_prefetch_depth(10.0, 2.0) == 6         # gather-bound
    assert suggest_prefetch_depth(100.0, 0.1) == 8        # clamped hi
    assert suggest_prefetch_depth(10.0, 2.0, lo=3, hi=4) == 4


# ---------------------------------------------------------------------------
# tentpole: every width trajectory is bit-identical to fixed-W sync
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("engine", ["sync", "pipelined"])
def test_autotune_bit_identical_to_fixed_sync(engine):
    data, obj = _setup(n=901, seed=1)
    ref = tree_maximize(obj, ChunkedSource.from_array(data, 128),
                        TreeConfig(k=8, capacity=60, seed=5),
                        wave_machines=3)
    auto = tree_maximize(obj, ChunkedSource.from_array(data, 128),
                         TreeConfig(k=8, capacity=60, seed=5, engine=engine,
                                    wave_autotune=True))
    _assert_identical(ref, auto)
    es = auto.engine_stats
    assert sum(es.width_trajectory) == ref.ingest.total_machines
    ndev = 1
    assert es.distinct_shapes <= shape_bound(ndev, ref.ingest.total_machines)


def test_autotune_respects_explicit_wave_machines_cap():
    """wave_machines without a byte budget is a capacity statement (W·μ
    device rows): the autoscaler may shrink waves below it but must never
    grow past it toward the full-resident footprint."""
    data, obj = _setup(n=901, seed=7)
    res = tree_maximize(obj, ChunkedSource.from_array(data, 128),
                        TreeConfig(k=8, capacity=60, seed=9,
                                   engine="pipelined", wave_autotune=True),
                        wave_machines=4)
    assert max(res.engine_stats.width_trajectory) <= 4
    assert res.ingest.peak_wave_rows <= 4 * 60
    ref = tree_maximize(obj, ChunkedSource.from_array(data, 128),
                        TreeConfig(k=8, capacity=60, seed=9),
                        wave_machines=4)
    _assert_identical(ref, res)


def test_autotune_respects_byte_budget_ladder_cap():
    data, obj = _setup(n=901, seed=2)
    mu, d = 60, data.shape[1]
    budget = 5 * mu * d * 4                  # ladder capped at W=5
    res = tree_maximize(obj, ChunkedSource.from_array(data, 128),
                        TreeConfig(k=8, capacity=mu, seed=3,
                                   engine="pipelined", wave_autotune=True,
                                   capacity_bytes=budget))
    assert max(res.engine_stats.width_trajectory) <= 5
    assert res.ingest.peak_wave_bytes <= budget
    ref = tree_maximize(obj, ChunkedSource.from_array(data, 128),
                        TreeConfig(k=8, capacity=mu, seed=3),
                        wave_machines=3)
    _assert_identical(ref, res)


@pytest.mark.parametrize("schedule", [
    [1], [2], [4], [8], [16],                    # every rung, ragged tails
    [1, 8, 1, 8, 1, 8],                          # forced oscillation
    [5, 1, 7, 2, 16, 1],                         # arbitrary adversarial mix
    [16, 16],                                    # oversized → clamped tail
], ids=["w1", "w2", "w4", "w8", "w16", "oscillate", "mixed", "oversized"])
@pytest.mark.parametrize("engine", ["sync", "pipelined"])
def test_adversarial_width_schedules_bit_identical(engine, schedule):
    data, obj = _setup(n=901, seed=3)
    ref = tree_maximize(obj, ChunkedSource.from_array(data, 128),
                        TreeConfig(k=8, capacity=60, seed=7),
                        wave_machines=3)
    got = tree_maximize(obj, ChunkedSource.from_array(data, 128),
                        TreeConfig(k=8, capacity=60, seed=7, engine=engine),
                        wave_schedule=schedule)
    _assert_identical(ref, got)
    assert sum(got.engine_stats.width_trajectory) == ref.ingest.total_machines


def test_adversarial_schedule_constrained_and_sharded():
    data, obj = _setup(n=780, seed=4)
    r = np.random.default_rng(11)
    attrs = np.stack([r.uniform(0.2, 1.0, len(data)),
                      r.integers(0, 3, len(data))], 1).astype(np.float32)
    cons = PartitionMatroid(caps=(3, 3, 3), col=1)

    def mk():
        return ShardedSource.from_arrays(
            [data[s:s + 130] for s in range(0, len(data), 130)],
            attrs=[attrs[s:s + 130] for s in range(0, len(data), 130)])

    ref = tree_maximize(obj, mk(), TreeConfig(k=8, capacity=60, seed=2),
                        wave_machines=2, constraint=cons)
    got = tree_maximize(obj, mk(),
                        TreeConfig(k=8, capacity=60, seed=2,
                                   engine="pipelined", hosts=2),
                        wave_schedule=[3, 1, 5, 1], constraint=cons)
    _assert_identical(ref, got)
    np.testing.assert_array_equal(ref.sel_attrs, got.sel_attrs)


def test_resume_across_different_width_trajectories(tmp_path, monkeypatch):
    """A checkpoint written by an adaptively-waved pipelined run must
    resume bit-identically under a *different* trajectory (fixed W, other
    schedule) — the checkpoint is width-agnostic state."""
    from repro.core import tree as tree_lib

    data, obj = _setup(n=700, seed=5)

    def run(ckpt=None, resume=False, **kw):
        return tree_maximize(
            obj, ChunkedSource.from_array(data, 100),
            TreeConfig(k=8, capacity=60, seed=6, checkpoint_dir=ckpt,
                       resume=resume, **kw.pop("cfg", {})), **kw)

    full = run(wave_machines=2)
    assert full.rounds >= 2

    ck = str(tmp_path / "ck")
    real_save = tree_lib._save_round

    def crash_after_round_1(d, round_idx, *a):
        real_save(d, round_idx, *a)
        if round_idx == 1:
            raise KeyboardInterrupt("simulated crash")

    monkeypatch.setattr(tree_lib, "_save_round", crash_after_round_1)
    with pytest.raises(KeyboardInterrupt):
        run(ckpt=ck, wave_schedule=[1, 5, 2],
            cfg=dict(engine="pipelined"))     # crash under trajectory A
    monkeypatch.setattr(tree_lib, "_save_round", real_save)

    for i, kw in enumerate((dict(wave_machines=2),          # fixed W
                            dict(wave_schedule=[7, 1, 1]),  # trajectory B
                            dict(cfg=dict(wave_autotune=True,
                                          engine="pipelined")))):  # adaptive
        import shutil
        ck_i = str(tmp_path / f"ck{i}")     # each variant resumes the CRASH
        shutil.copytree(ck, ck_i)           # checkpoint, not a predecessor's
        resumed = run(ckpt=ck_i, resume=True, **dict(kw))
        np.testing.assert_array_equal(resumed.sel_rows, full.sel_rows)
        np.testing.assert_array_equal(resumed.sel_mask, full.sel_mask)
        assert resumed.value == full.value
        assert resumed.oracle_calls == full.oracle_calls
        assert resumed.rounds == full.rounds
        assert resumed.machines_per_round == full.machines_per_round[1:]


# ---------------------------------------------------------------------------
# async checkpoint writer: identity, overlap stats, kill-mid-write
# ---------------------------------------------------------------------------


def test_async_checkpoint_bit_identical_and_overlapped(tmp_path):
    data, obj = _setup(n=901, seed=6)

    def run(mode_kw, ckpt):
        return tree_maximize(obj, ChunkedSource.from_array(data, 128),
                             TreeConfig(k=8, capacity=60, seed=4,
                                        checkpoint_dir=ckpt, **mode_kw),
                             wave_machines=3)

    plain = tree_maximize(obj, ChunkedSource.from_array(data, 128),
                          TreeConfig(k=8, capacity=60, seed=4),
                          wave_machines=3)
    sync = run({}, str(tmp_path / "s"))
    asyn = run(dict(async_checkpoint=True, engine="pipelined"),
               str(tmp_path / "a"))
    _assert_identical(plain, sync)
    _assert_identical(plain, asyn)
    assert plain.checkpoint_stats is None
    assert sync.checkpoint_stats.mode == "sync"
    assert sync.checkpoint_stats.hidden_s == 0.0
    cs = asyn.checkpoint_stats
    assert cs.mode == "async"
    assert len(cs.rounds) == asyn.rounds - 0  # one write per round boundary
    assert cs.write_s > 0
    assert 0.0 <= cs.hidden_fraction <= 1.0
    s = cs.summary()
    assert s["mode"] == "async" and s["rounds"] == len(cs.rounds)
    # both checkpoint files are complete and identical (same final round)
    a = np.load(os.path.join(str(tmp_path / "s"), "tree_round.npz"))
    b = np.load(os.path.join(str(tmp_path / "a"), "tree_round.npz"))
    for key in ("round", "rows", "mask", "best_rows", "best_mask",
                "best_val", "calls"):
        np.testing.assert_array_equal(a[key], b[key])


def test_async_checkpoint_killed_mid_write_resumes_exactly(tmp_path,
                                                          monkeypatch):
    """Kill the background writer mid-write (before the atomic rename):
    the error surfaces at the next barrier, the previous round's complete
    checkpoint survives on disk, and resuming from it finishes
    bit-identically to the uninterrupted run."""
    from repro.core import tree as tree_lib

    data, obj = _setup(n=700, seed=7)
    ck = str(tmp_path / "ck")

    def cfg(resume=False, async_ckpt=True):
        return TreeConfig(k=8, capacity=60, seed=6, checkpoint_dir=ck,
                          resume=resume, async_checkpoint=async_ckpt,
                          engine="pipelined")

    full = tree_maximize(obj, ChunkedSource.from_array(data, 100),
                         TreeConfig(k=8, capacity=60, seed=6),
                         wave_machines=2)
    assert full.rounds >= 3                  # need a round beyond the kill

    real_save = tree_lib._save_round

    def die_mid_write_round_2(d, round_idx, *a):
        if round_idx == 2:
            # partial tmp write then death — exactly what a kill leaves
            with open(os.path.join(d, "tree_round.tmp.npz"), "wb") as f:
                f.write(b"partial garbage")
            raise RuntimeError("writer killed mid-write")
        real_save(d, round_idx, *a)

    monkeypatch.setattr(tree_lib, "_save_round", die_mid_write_round_2)
    with pytest.raises(RuntimeError, match="killed mid-write"):
        tree_maximize(obj, ChunkedSource.from_array(data, 100), cfg(),
                      wave_machines=2)
    monkeypatch.setattr(tree_lib, "_save_round", real_save)

    # the atomic-rename contract: round 1's complete checkpoint survives
    saved = np.load(os.path.join(ck, "tree_round.npz"))
    assert int(saved["round"]) == 1

    resumed = tree_maximize(obj, ChunkedSource.from_array(data, 100),
                            cfg(resume=True), wave_machines=2)
    np.testing.assert_array_equal(resumed.sel_rows, full.sel_rows)
    np.testing.assert_array_equal(resumed.sel_mask, full.sel_mask)
    assert resumed.value == full.value
    assert resumed.oracle_calls == full.oracle_calls
    assert resumed.rounds == full.rounds
    assert resumed.machines_per_round == full.machines_per_round[1:]


def test_async_checkpoint_failure_injection_identity(tmp_path):
    """Failure injection + async checkpoints: the write barrier on the
    normal path must not disturb dropped-machine semantics."""
    data, obj = _setup(n=700, seed=8)
    fail = {0: [0, 2], 1: [1]}
    ref = tree_maximize(obj, ChunkedSource.from_array(data, 128),
                        TreeConfig(k=8, capacity=60, seed=7),
                        wave_machines=2, fail_machines=fail)
    got = tree_maximize(obj, ChunkedSource.from_array(data, 128),
                        TreeConfig(k=8, capacity=60, seed=7,
                                   engine="pipelined", wave_autotune=True,
                                   async_checkpoint=True,
                                   checkpoint_dir=str(tmp_path / "ck")),
                        fail_machines=fail)
    _assert_identical(ref, got)


# ---------------------------------------------------------------------------
# prefetch-depth plumbing (satellite)
# ---------------------------------------------------------------------------


def test_prefetch_depth_plumbs_and_preserves_output():
    data, obj = _setup(n=500, seed=9)
    ref = centralized_greedy(obj, jnp.asarray(data), 10)
    for depth in (1, 2, 5):
        st = centralized_greedy(obj, ChunkedSource.from_array(data, 97), 10,
                                chunk_rows=97, prefetch_depth=depth)
        assert float(st.value) == float(ref.value)
        np.testing.assert_array_equal(np.asarray(st.sel_rows),
                                      np.asarray(ref.sel_rows))
    # TreeConfig carries the knob and it lands on the source the wave
    # gathers actually consult (the default re-stream prefetch depth)
    src = ChunkedSource.from_array(data, 97)
    res = tree_maximize(obj, src,
                        TreeConfig(k=8, capacity=60, seed=1,
                                   prefetch_depth=4), wave_machines=2)
    assert res.value is not None
    assert src.prefetch_depth == 4
    with pytest.raises(AssertionError):
        TreeConfig(k=8, capacity=60, prefetch_depth=0)


def test_async_checkpoint_requires_checkpoint_dir():
    """async_checkpoint without a checkpoint_dir must be rejected up
    front, not silently write nothing."""
    with pytest.raises(AssertionError, match="checkpoint_dir"):
        TreeConfig(k=8, capacity=60, async_checkpoint=True)
