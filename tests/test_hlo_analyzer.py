"""The roofline's HLO analyzer: trip-count-exact flops, slice-aware bytes."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.launch.hlo_analyzer import analyze


def _scan_matmul(L, D):
    def one(x, w):
        return jnp.tanh(x @ w), None

    def f(x, ws):
        y, _ = jax.lax.scan(one, x, ws)
        return y
    x = jax.ShapeDtypeStruct((D, D), jnp.float32)
    ws = jax.ShapeDtypeStruct((L, D, D), jnp.float32)
    return jax.jit(f).lower(x, ws).compile()


def test_scan_flops_exact():
    for L in (4, 16):
        r = analyze(_scan_matmul(L, 64).as_text())
        assert r["flops"] == L * 2 * 64**3, (L, r["flops"])
        assert not r["unknown_trip_loops"]


def test_nested_scan_flops_exact():
    def one(x, w):
        return jnp.tanh(x @ w), None

    def inner(x, ws):
        return jax.lax.scan(one, x, ws)[0]

    def f(x, wss):
        return jax.lax.scan(lambda x, ws: (inner(x, ws), None), x, wss)[0]
    x = jax.ShapeDtypeStruct((64, 64), jnp.float32)
    wss = jax.ShapeDtypeStruct((3, 8, 64, 64), jnp.float32)
    r = analyze(jax.jit(f).lower(x, wss).compile().as_text())
    assert r["flops"] == 3 * 8 * 2 * 64**3


def test_dus_counts_slice_not_buffer():
    def dus(cache, upd, pos):
        return jax.lax.dynamic_update_slice(cache, upd, (0, pos, 0))
    cache = jax.ShapeDtypeStruct((8, 4096, 128), jnp.bfloat16)
    upd = jax.ShapeDtypeStruct((8, 1, 128), jnp.bfloat16)
    pos = jax.ShapeDtypeStruct((), jnp.int32)
    c = jax.jit(dus, donate_argnums=(0,)).lower(cache, upd, pos).compile()
    r = analyze(c.as_text())
    full = 8 * 4096 * 128 * 2
    assert r["hbm_bytes"] < 0.01 * full, r["hbm_bytes"]


def test_gather_counts_result_not_table():
    def lookup(emb, toks):
        return emb[toks]
    emb = jax.ShapeDtypeStruct((50000, 512), jnp.float32)
    toks = jax.ShapeDtypeStruct((8, 128), jnp.int32)
    r = analyze(jax.jit(lookup).lower(emb, toks).compile().as_text())
    result = 8 * 128 * 512 * 4
    table = 50000 * 512 * 4
    assert r["hbm_bytes"] <= 3 * result
    assert r["hbm_bytes"] < 0.2 * table


def test_remat_flops_counted():
    """jax.checkpoint re-runs the forward: analyzer must see ~2x dots."""
    def blk(x, w):
        return jnp.tanh(x @ w)

    def loss_plain(x, w):
        return jnp.sum(blk(x, w))

    def loss_remat(x, w):
        return jnp.sum(jax.checkpoint(blk)(x, w))
    x = jax.ShapeDtypeStruct((128, 128), jnp.float32)
    w = jax.ShapeDtypeStruct((128, 128), jnp.float32)
    fp = analyze(jax.jit(jax.grad(loss_plain, argnums=1)).lower(x, w)
                 .compile().as_text())["flops"]
    fr = analyze(jax.jit(jax.grad(loss_remat, argnums=1)).lower(x, w)
                 .compile().as_text())["flops"]
    assert fr >= fp  # remat can only add compute
