"""Sharding rules: divisibility fallback, param placement, batch specs."""
import jax
import jax.numpy as jnp
import pytest
from jax.sharding import PartitionSpec as P

from repro.sharding import fit_spec, param_spec, shard

MESH = {"pod": 2, "data": 16, "model": 16}


def test_fit_spec_divisible():
    assert fit_spec((256, 4096), ("data", "model"), MESH) == \
        P("data", "model")


def test_fit_spec_fallback_drops_nondivisible():
    # 6 heads cannot shard over 16 — falls back to replication
    assert fit_spec((6, 64), ("model", None), MESH) == P(None, None)
    # batch 1 cannot shard over (pod, data)
    assert fit_spec((1, 128), (("pod", "data"), None), MESH) == P(None, None)
    # batch 32 shards over pod*data=32
    assert fit_spec((32, 128), (("pod", "data"), None), MESH) == \
        P(("pod", "data"), None)
    # batch 16: prefix fallback to pod only? pod=2 divides 16 -> ("pod",)
    assert fit_spec((16, 128), (("pod", "data"), None), MESH)[0] is not None


def test_fit_spec_missing_axis_ignored():
    # single-pod mesh has no 'pod' axis
    mesh = {"data": 16, "model": 16}
    assert fit_spec((256, 128), (("pod", "data"), None), mesh) == \
        P("data", None)


def test_param_spec_rules():
    assert param_spec(("emb",), (50304, 2048)) == ("data", None)
    assert param_spec(("head",), (2048, 50304)) == (None, "model")
    assert param_spec(("attn", "wq"), (4, 2048, 4096)) == \
        (None, "data", "model")
    assert param_spec(("attn", "wo"), (4, 4096, 2048)) == \
        (None, "model", "data")
    assert param_spec(("moe", "experts", "w_gate"), (4, 64, 2048, 1408)) == \
        (None, "model", None, "data")
    assert param_spec(("moe", "experts", "w_down"), (4, 64, 1408, 2048)) == \
        (None, "model", "data", None)
    assert param_spec(("ln",), (4, 2048)) == (None, None)


def test_shard_noop_without_mesh():
    x = jnp.ones((8, 8))
    y = shard(x, "data", None)   # no ambient mesh -> identity
    assert (y == x).all()
