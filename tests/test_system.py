"""End-to-end behaviour tests for the paper's system.

The paper's headline empirical claims (§4.3, Table 3), scaled to CPU:
  1. TREE with severely limited capacity (down to 2k) stays within ~1% of
     centralized GREEDY on clustered data.
  2. RANDOM is far worse.
  3. Approximation quality is insensitive to capacity across a sweep.
Plus: the full LM path — submodular data selection → train a small LM →
loss drops; and serve path generates tokens.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core import (ExemplarClustering, TreeConfig, centralized_greedy,
                        random_subset, randgreedi, tree_maximize)
from repro.data import datasets
from repro.data.pipeline import DataConfig, SyntheticLM
from repro.data.selection import SelectionConfig, select_coreset
from repro.serve.serve_step import greedy_generate
from repro.train import optimizer as opt_lib
from repro.train import train_step as ts_lib


def _obj(data, ne=512, seed=0):
    r = np.random.default_rng(seed)
    E = data[r.choice(len(data), min(ne, len(data)), replace=False)]
    return ExemplarClustering(jnp.asarray(E))


def test_tree_close_to_centralized_even_at_2k():
    """Paper Fig 2: TREE copes with extremely limited capacity (2k)."""
    data = datasets.csn(n=4000, d=17)
    k = 20
    obj = _obj(data)
    dj = jnp.asarray(data)
    cg = centralized_greedy(obj, dj, k)
    tree = tree_maximize(obj, dj, TreeConfig(k=k, capacity=2 * k, seed=0))
    ratio = tree.value / float(cg.value)
    assert ratio > 0.95, ratio
    assert tree.rounds >= 3  # capacity 2k genuinely forces multiple rounds


def test_relative_error_under_1pct_table3_regime():
    """Paper Table 3: ≤~1% relative error at μ ∈ {200, 400, 800}."""
    data = datasets.parkinsons()
    k = 50
    obj = _obj(data, ne=512)
    dj = jnp.asarray(data)
    cg = float(centralized_greedy(obj, dj, k).value)
    for mu in (200, 400, 800):
        tree = tree_maximize(obj, dj, TreeConfig(k=k, capacity=mu, seed=0))
        rel_err = (cg - tree.value) / cg * 100
        assert rel_err < 2.0, (mu, rel_err)


def test_random_much_worse_than_tree():
    data = datasets.csn(n=4000, d=17)
    k = 20
    obj = _obj(data)
    dj = jnp.asarray(data)
    tree = tree_maximize(obj, dj, TreeConfig(k=k, capacity=100, seed=0))
    rnd = random_subset(obj, dj, k, jax.random.PRNGKey(0))
    assert tree.value > 1.1 * float(rnd.value)


def test_tree_matches_randgreedi_when_capacity_sufficient():
    """Paper: with μ ≥ √(nk) TREE reduces to the two-round regime."""
    data = datasets.parkinsons(n=2000)
    k = 10
    obj = _obj(data)
    dj = jnp.asarray(data)
    mu = int(np.ceil(np.sqrt(2000 * k)))
    tree = tree_maximize(obj, dj, TreeConfig(k=k, capacity=mu, seed=3))
    rg = randgreedi(obj, dj, k, int(np.ceil(2000 / mu)), jax.random.PRNGKey(3))
    assert abs(tree.value - float(rg.value)) / float(rg.value) < 0.05


def test_end_to_end_select_then_train():
    """The production path: distributed selection feeds LM training."""
    rng = np.random.default_rng(1)
    pool = jnp.asarray(rng.standard_normal((600, 32)).astype(np.float32))
    idx, _ = select_coreset(pool, SelectionConfig(k=8, capacity=64,
                                                  n_eval=128, seed=1))
    assert len(idx) == 8

    cfg = get_config("gemma-2b").reduced()
    opt_cfg = opt_lib.OptConfig(lr=3e-3, warmup_steps=2, total_steps=40,
                                moment_dtype="float32")
    state = ts_lib.init_train_state(cfg, opt_cfg, jax.random.PRNGKey(0))
    step = jax.jit(ts_lib.make_train_step(cfg, opt_cfg))
    data = SyntheticLM(DataConfig(vocab_size=cfg.vocab_size, seq_len=32,
                                  global_batch=8, seed=2))
    first = last = None
    for i in range(15):
        state, m = step(state, data.batch(i % 3))
        first = float(m["loss"]) if first is None else first
        last = float(m["loss"])
    assert last < first


def test_serve_generates():
    cfg = get_config("qwen3-8b").reduced()
    from repro.models import get_model
    params = get_model(cfg).init_params(cfg, jax.random.PRNGKey(0))
    prompt = jax.random.randint(jax.random.PRNGKey(1), (2, 8), 0,
                                cfg.vocab_size)
    out = greedy_generate(cfg, params, prompt, n_new=5)
    assert out.shape == (2, 5)
    assert bool(jnp.all((out >= 0) & (out < cfg.padded_vocab)))
