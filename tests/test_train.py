"""Training substrate: loss goes down, grad-accum equivalence, checkpoint
roundtrip + manager rotation, straggler detection, data determinism."""
import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.data.pipeline import DataConfig, SyntheticLM
from repro.train import checkpoint as ckpt_lib
from repro.train import optimizer as opt_lib
from repro.train import train_step as ts_lib
from repro.train.fault_tolerance import CheckpointManager, StragglerMonitor


def _tiny_cfg():
    return get_config("qwen3-8b").reduced()


def test_loss_decreases():
    cfg = _tiny_cfg()
    opt_cfg = opt_lib.OptConfig(lr=3e-3, warmup_steps=5, total_steps=60,
                                moment_dtype="float32")
    state = ts_lib.init_train_state(cfg, opt_cfg, jax.random.PRNGKey(0))
    step = jax.jit(ts_lib.make_train_step(cfg, opt_cfg))
    data = SyntheticLM(DataConfig(vocab_size=cfg.vocab_size, seq_len=32,
                                  global_batch=8, seed=0))
    losses = []
    for i in range(30):
        state, m = step(state, data.batch(i % 4))
        losses.append(float(m["loss"]))
    assert np.mean(losses[-5:]) < np.mean(losses[:5]) - 0.2, losses[:3] + losses[-3:]


def test_grad_accum_equivalence():
    """microbatches=2 must equal microbatches=1 (same data, same update)."""
    import dataclasses
    cfg1 = dataclasses.replace(_tiny_cfg(), microbatches=1)
    cfg2 = dataclasses.replace(_tiny_cfg(), microbatches=2)
    opt_cfg = opt_lib.OptConfig(lr=1e-3, moment_dtype="float32")
    state1 = ts_lib.init_train_state(cfg1, opt_cfg, jax.random.PRNGKey(0))
    state2 = jax.tree_util.tree_map(lambda x: x, state1)
    batch = SyntheticLM(DataConfig(vocab_size=cfg1.vocab_size, seq_len=32,
                                   global_batch=4, seed=1)).batch(0)
    s1, m1 = jax.jit(ts_lib.make_train_step(cfg1, opt_cfg))(state1, batch)
    s2, m2 = jax.jit(ts_lib.make_train_step(cfg2, opt_cfg))(state2, batch)
    np.testing.assert_allclose(float(m1["loss"]), float(m2["loss"]),
                               rtol=1e-5)
    a = jax.tree_util.tree_leaves(s1["params"])
    b = jax.tree_util.tree_leaves(s2["params"])
    for x, y in zip(a, b):
        np.testing.assert_allclose(x, y, rtol=2e-4, atol=2e-5)


def test_checkpoint_roundtrip_and_rotation():
    cfg = _tiny_cfg()
    opt_cfg = opt_lib.OptConfig(moment_dtype="float32")
    state = ts_lib.init_train_state(cfg, opt_cfg, jax.random.PRNGKey(0))
    with tempfile.TemporaryDirectory() as td:
        mgr = CheckpointManager(td, every_steps=1, keep=2)
        for s in range(1, 5):
            mgr.maybe_save(s, state)
        assert ckpt_lib.latest_step(td) == 4
        dirs = sorted(os.listdir(td))
        assert len(dirs) == 2  # rotation kept last 2
        restored, step = mgr.restore_latest(state)
        assert step == 4
        for x, y in zip(jax.tree_util.tree_leaves(state),
                        jax.tree_util.tree_leaves(restored)):
            np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


def test_restart_replays_identical_batches():
    d1 = SyntheticLM(DataConfig(vocab_size=1000, seq_len=16, global_batch=4,
                                seed=9))
    d2 = SyntheticLM(DataConfig(vocab_size=1000, seq_len=16, global_batch=4,
                                seed=9))
    for step in (0, 7, 123):
        np.testing.assert_array_equal(np.asarray(d1.batch(step)["tokens"]),
                                      np.asarray(d2.batch(step)["tokens"]))


def test_straggler_monitor_flags_slow_steps():
    import time
    mon = StragglerMonitor(factor=3.0)
    for _ in range(8):
        mon.start(); time.sleep(0.002); assert not mon.stop()
    mon.start(); time.sleep(0.05)
    assert mon.stop()


def test_schedule_warmup_and_decay():
    oc = opt_lib.OptConfig(lr=1e-3, warmup_steps=10, total_steps=100)
    lr0 = float(opt_lib.schedule(oc, jnp.int32(1)))
    lr10 = float(opt_lib.schedule(oc, jnp.int32(10)))
    lr100 = float(opt_lib.schedule(oc, jnp.int32(100)))
    assert lr0 < lr10
    assert abs(lr10 - 1e-3) < 1e-6
    assert lr100 < 0.2 * lr10


def test_lm_loss_vocab_padding_masked():
    from repro.train.train_step import lm_loss
    B, S, V, Vp = 2, 8, 50, 64
    key = jax.random.PRNGKey(0)
    logits = jax.random.normal(key, (B, S, Vp))
    toks = jax.random.randint(key, (B, S), 0, V)
    # poisoning padded logits must not change the loss
    poisoned = logits.at[..., V:].set(100.0)
    l1 = float(lm_loss(logits, toks, vocab_size=V))
    l2 = float(lm_loss(poisoned, toks, vocab_size=V))
    np.testing.assert_allclose(l1, l2, rtol=1e-6)
