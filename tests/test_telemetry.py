"""Unified telemetry layer (repro.engine.telemetry): the tracer must be a
pure *observer* — an instrumented run is bit-identical to an
uninstrumented one across engines, constraints, and dtypes — while its
exported span stream carries enough to reconstruct the engine's reported
overlap ratio to float precision, the metrics registry is a faithful
projection of the stats dataclasses, and the run manifest survives a
kill mid-write."""
import json
import os
import threading

import numpy as np
import jax.numpy as jnp
import pytest

from repro.core import (ChunkedSource, ExemplarClustering, Knapsack,
                        QuantizedSource, TreeConfig, tree_maximize)
from repro.engine import (MetricsRegistry, RunManifest, Tracer,
                          build_manifest, dtype_label, feed_result_metrics,
                          format_report, profiler_session, read_jsonl_events,
                          top_spans, wave_overlap_from_spans)
from repro.engine.telemetry import (MANIFEST_NAME, SCHEMA_VERSION,
                                    config_fingerprint)
from repro.launch import tracetool


def _setup(n=601, d=8, ne=96, seed=0):
    r = np.random.default_rng(seed)
    data = r.standard_normal((n, d)).astype(np.float32)
    E = data[r.choice(n, ne, replace=False)]
    return data, ExemplarClustering(jnp.asarray(E))


def _assert_identical(a, b):
    np.testing.assert_array_equal(a.sel_rows, b.sel_rows)
    np.testing.assert_array_equal(a.sel_mask, b.sel_mask)
    assert a.value == b.value                      # bit-identical, no rtol
    assert a.oracle_calls == b.oracle_calls
    assert a.rounds == b.rounds
    assert a.machines_per_round == b.machines_per_round
    assert a.round_values == b.round_values


def _run(data, obj, *, tracer=None, engine="sync", dtype=None,
         constraint=None, attrs=None, W=3, **cfg_kw):
    src = ChunkedSource.from_array(data, 128, attrs=attrs)
    if dtype is not None and dtype != "fp32":
        src = QuantizedSource(src, store_dtype=dtype)
    cfg = TreeConfig(k=6, capacity=60, seed=4, engine=engine,
                     telemetry=tracer, **cfg_kw)
    return tree_maximize(obj, src, cfg, wave_machines=W,
                         constraint=constraint)


# ---------------------------------------------------------------------------
# tracer core: spans, instants, tracks, thread safety
# ---------------------------------------------------------------------------


def test_span_context_manager_nests_and_orders():
    tr = Tracer()
    with tr.span("outer", "round", step=1) as args:
        with tr.span("inner", "wave"):
            pass
        args["rows"] = 7
    spans = tr.spans()
    assert [s.name for s in spans] == ["inner", "outer"]   # end order
    inner, outer = spans
    # proper nesting: outer brackets inner on the same clock
    assert outer.t0 <= inner.t0 <= inner.t1 <= outer.t1
    assert outer.args == {"step": 1, "rows": 7}            # late attrs stick
    assert tr.spans(cat="wave") == [inner]
    assert tr.spans(name="outer") == [outer]


def test_instants_and_named_tracks():
    tr = Tracer()
    tr.instant("evict", "fault", host=2)
    tr.emit("host-gather", "host", 1.0, 2.0, track="host-1", rows=5)
    ev_i, ev_x = tr.events
    assert ev_i.phase == "i" and ev_i.t0 == ev_i.t1
    assert ev_x.phase == "X" and ev_x.dur_s == 1.0
    names = tr.track_names()
    # the instant's track is the emitting thread; the span's is named
    assert names[ev_i.track] == threading.current_thread().name
    assert names[ev_x.track] == "host-1"
    assert ev_i.track != ev_x.track


def test_tracer_thread_safety():
    tr = Tracer()
    n_threads, n_spans = 8, 200
    # hold every thread at the gate so all are alive at once (Python
    # recycles thread idents, so early exits would fold tracks together)
    gate = threading.Barrier(n_threads)

    def work(i):
        gate.wait()
        for j in range(n_spans):
            with tr.span(f"w{i}", "wave", j=j):
                pass

    threads = [threading.Thread(target=work, args=(i,), name=f"t{i}")
               for i in range(n_threads)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert len(tr.events) == n_threads * n_spans
    # one auto-registered track per emitting thread, none lost
    assert sorted(tr.track_names().values()) == sorted(
        f"t{i}" for i in range(n_threads))
    per = {}
    for e in tr.events:
        per[e.name] = per.get(e.name, 0) + 1
    assert all(v == n_spans for v in per.values())


# ---------------------------------------------------------------------------
# exporters: schema round-trips
# ---------------------------------------------------------------------------


def test_chrome_trace_schema_roundtrip(tmp_path):
    tr = Tracer()
    with tr.span("gather", "wave", wave=0, rows=10):
        pass
    tr.instant("hedge", "fault", wave=0)
    path = str(tmp_path / "trace.json")
    tr.export_chrome_trace(path)
    doc = json.load(open(path))
    assert doc["otherData"]["schema_version"] == SCHEMA_VERSION
    evs = doc["traceEvents"]
    meta = [e for e in evs if e["ph"] == "M"]
    assert meta and meta[0]["name"] == "thread_name"
    xs = [e for e in evs if e["ph"] == "X"]
    ins = [e for e in evs if e["ph"] == "i"]
    assert len(xs) == 1 and len(ins) == 1
    assert xs[0]["cat"] == "wave" and xs[0]["args"] == {"wave": 0, "rows": 10}
    assert isinstance(xs[0]["ts"], float) and isinstance(xs[0]["dur"], float)
    assert ins[0]["s"] == "t"
    # tracetool reads it back with timestamps intact to ~float precision
    events, tracks = tracetool.load_trace(path)
    assert len(events) == 2 and tracks
    got = next(e for e in events if e.phase == "X")
    want = next(e for e in tr.events if e.phase == "X")
    assert abs(got.dur_s - want.dur_s) < 1e-9


def test_jsonl_roundtrip_exact(tmp_path):
    tr = Tracer()
    with tr.span("solve", "wave", wave=3):
        pass
    path = str(tmp_path / "events.jsonl")
    tr.export_jsonl(path)
    recs = read_jsonl_events(path)
    assert recs[0]["type"] == "meta"
    assert recs[0]["schema_version"] == SCHEMA_VERSION
    span = next(r for r in recs if r["type"] == "span")
    want = tr.events[0]
    # JSON float repr round-trips exactly — no epsilon needed
    assert span["t0"] == want.t0 - tr.epoch
    assert span["t1"] == want.t1 - tr.epoch
    assert span["args"] == {"wave": 3}
    events, tracks = tracetool.load_trace(path)
    assert events[0].t1 - events[0].t0 == want.dur_s


# ---------------------------------------------------------------------------
# metrics registry
# ---------------------------------------------------------------------------


def test_registry_instruments_and_keys(tmp_path):
    reg = MetricsRegistry()
    reg.counter("engine.waves", engine="sync").inc(3)
    reg.counter("engine.waves", engine="sync").inc()        # same instrument
    reg.gauge("overlap", engine="pipelined").set(0.75)
    h = reg.histogram("gather_s", engine="pipelined", host=1)
    for v in (0.1, 0.3, 0.2):
        h.observe(v)
    snap = reg.snapshot()
    assert snap["counters"]["engine.waves{engine=sync}"] == 4
    assert snap["gauges"]["overlap{engine=pipelined}"] == 0.75
    # labels sort in the key regardless of call order
    hs = snap["histograms"]["gather_s{engine=pipelined,host=1}"]
    assert hs["count"] == 3 and hs["min"] == 0.1 and hs["max"] == 0.3
    path = str(tmp_path / "metrics.json")
    reg.export_json(path)
    assert json.load(open(path))["counters"] == snap["counters"]


def test_feed_result_metrics_projects_stats():
    data, obj = _setup()
    res = _run(data, obj, engine="pipelined")
    reg = MetricsRegistry()
    feed_result_metrics(reg, res)
    snap = reg.snapshot()
    es = res.engine_stats
    assert snap["counters"]["engine.waves{engine=pipelined}"] == es.waves
    assert (snap["counters"]["engine.bytes_moved{engine=pipelined}"]
            == es.bytes_moved)
    assert (snap["gauges"]["engine.overlap_ratio{engine=pipelined}"]
            == es.overlap_ratio)
    gh = snap["histograms"]["engine.gather_s{engine=pipelined}"]
    assert gh["count"] == es.waves
    assert abs(gh["sum"] - es.gather_s) < 1e-9


# ---------------------------------------------------------------------------
# engine instrumentation: span invariants, stall accounting, overlap
# ---------------------------------------------------------------------------


def test_span_counts_pipelined_equals_sync():
    data, obj = _setup(seed=3)
    tr_s, tr_p = Tracer(), Tracer()
    a = _run(data, obj, tracer=tr_s, engine="sync")
    b = _run(data, obj, tracer=tr_p, engine="pipelined")
    _assert_identical(a, b)
    for name in ("gather", "solve"):
        assert (len(tr_s.spans(cat="wave", name=name))
                == len(tr_p.spans(cat="wave", name=name))
                == a.engine_stats.waves)
    # both engines close the run with one run-span and per-round spans
    for tr, res in ((tr_s, a), (tr_p, b)):
        assert len(tr.spans(cat="run")) == 1
        assert len(tr.spans(cat="round")) == res.rounds
    # stall spans exist only where a second thread can block
    assert tr_s.spans(cat="stall") == []
    # pipelined producer runs on its own named thread → ≥ 2 tracks
    assert len(tr_p.track_names()) >= 2
    assert "wave-prefetch" in tr_p.track_names().values()


def test_wave_traces_carry_timestamps_and_stall():
    data, obj = _setup(seed=5)
    res = _run(data, obj, engine="pipelined")
    traces = res.engine_stats.traces
    assert traces and all(t.t_end > t.t_start > 0.0 for t in traces)
    assert all(t.stall_s >= 0.0 for t in traces)
    # span-based wall is what the stamps reconstruct, and the scheduler
    # loop can only add wall *around* the waves, never remove it
    es = res.engine_stats
    assert 0.0 < es.span_wall_s <= es.wall_s + 1e-9
    assert es.overlap_ratio_legacy <= es.overlap_ratio + 1e-12


def test_trace_overlap_matches_engine_stats(tmp_path):
    data, obj = _setup(seed=7)
    tr = Tracer()
    res = _run(data, obj, tracer=tr, engine="pipelined")
    path = str(tmp_path / "trace.json")
    tr.export_chrome_trace(path)
    events, _ = tracetool.load_trace(path)
    _, ov, n_waves = tracetool.span_overlap(events)
    assert n_waves == res.engine_stats.waves
    # acceptance bound: the exported trace reconstructs the reported
    # overlap within 1e-6 (float µs round-trip keeps it far tighter)
    assert abs(ov - res.engine_stats.overlap_ratio) < 1e-6


def test_host_gather_spans_on_named_tracks():
    data, obj = _setup(seed=9)
    tr = Tracer()
    _run(data, obj, tracer=tr, engine="pipelined", hosts=2)
    host_spans = tr.spans(cat="host", name="host-gather")
    assert host_spans
    names = tr.track_names()
    lanes = {names[s.track] for s in host_spans}
    assert lanes == {"host-0", "host-1"}
    assert all("wave" in s.args and "rows" in s.args for s in host_spans)


# ---------------------------------------------------------------------------
# bit-identity: telemetry is observation only
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("engine", ["sync", "pipelined"])
@pytest.mark.parametrize("dtype", ["fp32", "int8"])
def test_instrumented_bit_identical(engine, dtype):
    data, obj = _setup(seed=11)
    plain = _run(data, obj, engine=engine, dtype=dtype)
    traced = _run(data, obj, tracer=Tracer(), engine=engine, dtype=dtype)
    _assert_identical(plain, traced)


def test_instrumented_bit_identical_constrained():
    data, obj = _setup(seed=13)
    r = np.random.default_rng(7)
    attrs = r.uniform(0.2, 1.0, (len(data), 1)).astype(np.float32)
    spec = Knapsack(budget=3.0, col=0)
    plain = _run(data, obj, engine="pipelined", constraint=spec, attrs=attrs)
    traced = _run(data, obj, tracer=Tracer(), engine="pipelined",
                  constraint=spec, attrs=attrs)
    _assert_identical(plain, traced)
    np.testing.assert_array_equal(plain.sel_attrs, traced.sel_attrs)


def test_config_fingerprint_ignores_telemetry():
    a = TreeConfig(k=6, capacity=60, seed=4)
    b = TreeConfig(k=6, capacity=60, seed=4, telemetry=Tracer())
    c = TreeConfig(k=6, capacity=61, seed=4)
    assert config_fingerprint(a) == config_fingerprint(b)
    assert config_fingerprint(a) != config_fingerprint(c)


# ---------------------------------------------------------------------------
# run manifest: build, validate, atomicity, report formatting
# ---------------------------------------------------------------------------


def test_manifest_built_and_written_next_to_checkpoints(tmp_path):
    data, obj = _setup(seed=15)
    tr = Tracer()
    res = _run(data, obj, tracer=tr, engine="pipelined", dtype="int8",
               checkpoint_dir=str(tmp_path))
    m = res.manifest
    assert m is not None and m.validate() == []
    assert m.dtype == "int8" and m.source_fingerprint
    assert m.run["value"] == float(res.value)
    assert m.engine["width_trajectory"] == res.engine_stats.width_trajectory
    assert m.phases["total_wall_s"] > 0
    assert m.phases["round0_wall_s"] == res.round_walls[0]
    assert m.faults is None        # no fault policy armed on this run
    # written atomically next to the checkpoints, loads back equal
    on_disk = RunManifest.load(os.path.join(str(tmp_path), MANIFEST_NAME))
    assert on_disk.validate() == []
    assert on_disk.config_fingerprint == m.config_fingerprint
    assert on_disk.run == m.run
    # ... and the tracer's registry was fed the result's stats
    snap = tr.metrics.snapshot()
    assert (snap["counters"]["engine.waves{engine=pipelined}"]
            == res.engine_stats.waves)


def test_manifest_atomic_under_kill_mid_write(tmp_path, monkeypatch):
    data, obj = _setup(seed=17)
    res = _run(data, obj)
    m = build_manifest(TreeConfig(k=6, capacity=60, seed=4), res,
                       n=len(data), d=data.shape[1], dtype_label="fp32")
    path = str(tmp_path / "run_manifest.json")
    m.write(path)
    before = open(path).read()

    # kill the writer between tmp-file write and the atomic rename
    def boom(src, dst):
        raise KeyboardInterrupt("killed mid-write")

    monkeypatch.setattr(os, "replace", boom)
    m.run["value"] = -1.0
    with pytest.raises(KeyboardInterrupt):
        m.write(path)
    monkeypatch.undo()
    # the published manifest is byte-identical to the pre-kill version
    assert open(path).read() == before
    assert RunManifest.load(path).validate() == []


def test_manifest_validate_reports_missing_fields():
    m = RunManifest(config={}, config_fingerprint="", run={})
    problems = m.validate()
    assert any("config" in p for p in problems)
    assert any("'value'" in p for p in problems)
    m = RunManifest(config={"k": 1}, config_fingerprint="ab", dtype="fp32",
                    run={"value": 1.0, "rounds": 1, "oracle_calls": 2},
                    phases={"total_wall_s": 0.1},
                    engine={"engine": "sync"})
    assert any("engine section missing" in p for p in m.validate())


def test_format_report_matches_legacy_lines():
    data, obj = _setup(seed=19)
    res = _run(data, obj, engine="pipelined")
    cfg = TreeConfig(k=6, capacity=60, seed=4, engine="pipelined")
    m = build_manifest(cfg, res, n=len(data), d=data.shape[1],
                       dtype_label="fp32")
    m.feasibility = {"ok": True, "detail": "knapsack 2.9/3.0"}
    m.recheck = {"fp32": 0.5, "solve": 0.5, "rel_gap": 0.0, "status": "PASS"}
    lines = format_report(m)
    es, ing = res.engine_stats, res.ingest
    assert lines[0] == (f"TREE: f={res.value:.6f} rounds={res.rounds} "
                        f"machines/round={res.machines_per_round} "
                        f"oracle_calls={res.oracle_calls}")
    engine_line = next(l for l in lines if l.startswith("engine:"))
    assert engine_line == (
        f"engine: {es.engine} hosts={es.hosts} wall={es.wall_s:.3f}s "
        f"gather={es.gather_s:.3f}s solve={es.solve_s:.3f}s "
        f"overlap={es.overlap_ratio:.2%} bytes={es.bytes_moved} "
        f"max_in_flight={es.max_in_flight}")
    bytes_line = next(l for l in lines if l.startswith("bytes:"))
    assert f"total_bytes={ing.total_bytes}" in bytes_line
    assert "autotune:" not in "".join(lines)       # wave_autotune off
    assert lines[-2] == "feasibility: OK (knapsack 2.9/3.0)"
    assert lines[-1] == ("recheck: fp32=0.500000 solve=0.500000 "
                         "rel_gap=0.00e+00 PASS")


def test_dtype_label_vocabulary():
    assert dtype_label(np.float32) == "fp32"
    assert dtype_label(np.int8) == "int8"
    assert dtype_label(jnp.bfloat16) == "bf16"


# ---------------------------------------------------------------------------
# span-stream views + tracetool CLI
# ---------------------------------------------------------------------------


def test_wave_overlap_from_spans_arithmetic():
    # two waves, second gather fully hidden under first solve
    gathers = [(0.0, 1.0), (1.0, 2.0)]
    solves = [(1.0, 3.0), (3.0, 4.0)]
    wall, ov = wave_overlap_from_spans(gathers, solves)
    assert wall == 4.0
    assert ov == pytest.approx((2.0 + 3.0 - 4.0) / 2.0)
    assert wave_overlap_from_spans([], []) == (0.0, 0.0)
    # serialized spans → zero overlap, clamped
    wall, ov = wave_overlap_from_spans([(0.0, 1.0)], [(1.5, 2.0)])
    assert ov == 0.0


def test_top_spans_aggregates():
    tr = Tracer()
    for w in range(3):
        tr.emit("gather", "wave", 0.0, 1.0, wave=w)
    tr.emit("solve", "wave", 0.0, 5.0)
    tr.instant("hedge", "fault")
    rows = top_spans(tr.events)
    assert rows[0]["name"] == "solve" and rows[0]["total_s"] == 5.0
    assert rows[1] == {"cat": "wave", "name": "gather", "count": 3,
                       "total_s": 3.0, "mean_s": 1.0}


def test_tracetool_main_validates_and_cross_checks(tmp_path, capsys):
    data, obj = _setup(seed=21)
    tr = Tracer()
    res = _run(data, obj, tracer=tr, engine="pipelined")
    trace = str(tmp_path / "trace.json")
    manifest = str(tmp_path / "m.json")
    tr.export_chrome_trace(trace)
    res.manifest = build_manifest(
        TreeConfig(k=6, capacity=60, seed=4, engine="pipelined"), res,
        n=len(data), d=data.shape[1], dtype_label="fp32")
    res.manifest.write(manifest)
    assert tracetool.main([trace, "--manifest", manifest]) == 0
    out = capsys.readouterr().out
    assert "manifest: OK" in out
    assert "PASS" in next(l for l in out.splitlines()
                          if l.startswith("cross-check:"))
    # corrupt the reported overlap → cross-check must fail the run
    bad = json.load(open(manifest))
    bad["engine"]["overlap_ratio"] = 0.123456
    json.dump(bad, open(manifest, "w"))
    assert tracetool.main([trace, "--manifest", manifest]) != 0


def test_tracetool_rejects_invalid_manifest(tmp_path, capsys):
    tr = Tracer()
    tr.emit("gather", "wave", 0.0, 1.0)
    trace = str(tmp_path / "t.json")
    tr.export_chrome_trace(trace)
    bad = str(tmp_path / "bad.json")
    json.dump({"schema_version": 1, "run": {}}, open(bad, "w"))
    assert tracetool.main([trace, "--manifest", bad]) != 0
    assert "INVALID" in capsys.readouterr().out


def test_profiler_session_noop_without_dir():
    with profiler_session(None):
        pass
    with profiler_session(""):
        pass
